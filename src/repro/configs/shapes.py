"""Assigned input shapes (the same 4 for every LM arch)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(arch_cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason if skipped (DESIGN.md §6)."""
    if shape.name == "long_500k" and not arch_cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
