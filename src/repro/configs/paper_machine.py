"""The paper's experimental platform (§4.1), as a MachineModel.

Two hexa-core Xeon X5650 @2.66 GHz (12 cores, ATLAS BLAS) + eight NVIDIA
Tesla C2050 (Fermi) GPUs on 4 PCIe switches (2 GPUs share a 16x link when
more than 4 GPUs are used). Each running GPU monopolizes one CPU core.

Rates are effective fp64 rates for PLASMA tile kernels, calibrated from the
public performance of those kernels on that hardware generation:
  * X5650 core: ~10.6 GFLOP/s peak fp64; ATLAS DGEMM ~85% -> ~9 GFLOP/s;
    panel/factorization kernels are less efficient.
  * C2050: 515 GFLOP/s peak fp64; MAGMA DGEMM ~60-65% -> ~300 GFLOP/s;
    memory-bound or panel kernels much lower, matching the strong
    kernel-dependent CPU/GPU speedup spread the paper's model captures.
PCIe 2.0 16x: ~8 GB/s asymptotic per switch.
"""
from __future__ import annotations

from repro.core.machine import MachineModel, ResourceClass, make_machine

GF = 1e9

CPU_CLASS = ResourceClass(
    name="cpu",
    rates={
        # tile kernels (fp64, ATLAS on X5650, per core)
        "gemm": 9.0 * GF,
        "syrk": 8.5 * GF,
        "trsm": 8.0 * GF,
        "potrf": 5.5 * GF,
        "getrf": 4.5 * GF,
        "geqrt": 4.0 * GF,
        "tsqrt": 4.0 * GF,
        "ormqr": 7.0 * GF,
        "tsmqr": 7.5 * GF,
        "gessm": 7.5 * GF,
        "tstrf": 4.5 * GF,
        "ssssm": 8.0 * GF,
    },
    default_rate=7.0 * GF,
)

GPU_CLASS = ResourceClass(
    name="gpu",
    rates={
        # tile kernels (fp64, CUDA/MAGMA on C2050)
        "gemm": 300.0 * GF,
        "syrk": 250.0 * GF,
        "trsm": 160.0 * GF,
        "potrf": 30.0 * GF,  # small-panel factorizations are GPU-unfriendly
        "getrf": 25.0 * GF,
        "geqrt": 20.0 * GF,
        "tsqrt": 20.0 * GF,
        "ormqr": 140.0 * GF,
        "tsmqr": 150.0 * GF,
        "gessm": 150.0 * GF,
        "tstrf": 25.0 * GF,
        "ssssm": 200.0 * GF,
    },
    default_rate=120.0 * GF,
)

TOTAL_CORES = 12
PCIE_BANDWIDTH = 8e9  # bytes/s, asymptotic 16x
PCIE_LATENCY = 15e-6


def scaled_machine(
    n_gpus: int = 24,
    n_cpus: int = 8,
    gpus_per_switch: int = 2,
) -> MachineModel:
    """A beyond-paper platform: up to 32 heterogeneous resources.

    Same resource classes and PCIe model as the paper box, but with the
    counts the original hardware never had (the scheduler-scaling sweeps
    use 8 CPUs + 24 GPUs = 32 resources on NT=32/64 tile grids). GPUs do
    not pin compute cores here — ``n_cpus`` is the compute-CPU count — so
    the resource total is exactly ``n_cpus + n_gpus``.
    """
    n_res = n_cpus + n_gpus
    if not 0 < n_res <= 32:
        raise ValueError(f"scaled_machine supports 1..32 resources, got {n_res}")
    return make_machine(
        n_cpus=n_cpus,
        n_gpus=n_gpus,
        cpu_class=CPU_CLASS,
        gpu_class=GPU_CLASS,
        pcie_bandwidth=PCIE_BANDWIDTH,
        pcie_latency=PCIE_LATENCY,
        gpus_per_switch=gpus_per_switch,
        gpu_pins_cpu=False,
    )


def paper_machine(n_gpus: int, total_cores: int = TOTAL_CORES) -> MachineModel:
    """The paper machine with ``n_gpus`` GPUs enabled (0..8).

    With <=4 GPUs each GPU gets a dedicated switch; beyond that two GPUs
    share one switch's bandwidth (handled by make_machine's link groups).
    """
    if not 0 <= n_gpus <= 8:
        raise ValueError("the platform has at most 8 GPUs")
    return make_machine(
        n_cpus=total_cores,
        n_gpus=n_gpus,
        cpu_class=CPU_CLASS,
        gpu_class=GPU_CLASS,
        pcie_bandwidth=PCIE_BANDWIDTH,
        pcie_latency=PCIE_LATENCY,
        gpus_per_switch=2,
        gpu_pins_cpu=True,
    )
