"""internvl2-76b [vlm]: 80L d8192 64H (GQA kv=8) ff28672 vocab128256 —
InternLM2-76B language backbone; InternViT patch embeddings STUBBED
(input_specs provides precomputed patch embeddings).
[arXiv:2404.16821; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    act="silu", rope_style="full",
    frontend_tokens=256, frontend_dim=3200,  # InternViT-6B width stub
    param_dtype="bfloat16",
)
