"""Built-in placement policies, registered under their public names.

``heft`` / ``dada`` / ``dual`` / ``ws`` are the paper's strategies, ported
from ``repro.core`` unchanged (their placements stay bit-for-bit identical
to ``repro.core._reference``). ``random`` and ``locality`` are new
score-matrix policies proving the :class:`~repro.sched.policy.Policy`
protocol is generic — each is ~20 lines over the array-native core:

  * ``random`` — seeded uniform placement, the model-oblivious *baseline
    floor*: any model-driven policy should beat it, and its seeded
    determinism makes it a cheap harness for simulator invariants;
  * ``locality`` — greedy min-transfer placement à la graph-partition
    scheduling (Wu et al., arXiv:1502.07451): each task goes to the
    resource minimizing predicted input-transfer time plus current
    backlog, ignoring compute-speed heterogeneity entirely. Data pulls
    work to where its bytes already live — the paper's affinity idea with
    the dual-approximation machinery stripped away.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.dada import DADA, DualApprox
from repro.core.dag import Task
from repro.core.heft import HEFT
from repro.core.simulator import Simulator
from repro.runtime.queues import WorkSteal

from .policy import ScoreMatrixPolicy, class_duration_matrix
from .registry import register


class RandomPolicy(ScoreMatrixPolicy):
    """Uniform-random placement (seeded, deterministic): the baseline floor."""

    allow_steal = False
    owner_lifo = False
    load_aware = False

    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self.name = f"random({seed})" if seed else "random"
        self._rng = np.random.default_rng(seed)

    def init(self, sim: Simulator) -> None:
        # reseed per simulation: two runs with the same (sim seed, policy
        # seed) draw identical placement streams
        self._rng = np.random.default_rng(self.seed)

    def score_matrix(self, sim: Simulator, ready: Sequence[Task]) -> np.ndarray:
        return self._rng.random((len(ready), len(sim.machine.resources)))


class LocalityPolicy(ScoreMatrixPolicy):
    """Greedy min-transfer placement (graph-partition style).

    Score = predicted time to move the task's missing inputs to the
    resource's memory (asymptotic-bandwidth model over the residency
    bitmasks — the same batched rows HEFT's +CP term uses). The load-aware
    driver adds each resource's current backlog, so ties on fully-resident
    data spread across workers instead of piling onto resource 0, and
    charges the chosen resource the predicted duration.
    """

    name = "locality"
    allow_steal = False
    owner_lifo = False
    load_aware = True

    def score_matrix(self, sim: Simulator, ready: Sequence[Task]) -> np.ndarray:
        tids = [t.tid for t in ready]
        rows = sim.transfer_model.task_input_transfer_rows(
            sim.arrays, tids,
            [r.mem for r in sim.machine.resources], sim.residency,
        )
        return np.asarray(rows, dtype=np.float64)


class PriorityPolicy(ScoreMatrixPolicy):
    """Strict-weight tenant priority over earliest-finish placement.

    Score = predicted input-transfer time + static duration (HEFT's EFT
    decomposition without the backlog, which the load-aware driver adds).
    The tenant's submit-time ``priority`` divides the backlog a tenant
    perceives: a priority-2 tenant sees only half the queue, so its tasks
    jump ahead of priority-1 work contending for the same resource, while
    the *real* shared time-stamps stay unscaled.  Starvation is the
    policy's documented failure mode — that is what :class:`WFQPolicy`
    exists to fix.
    """

    name = "priority"
    allow_steal = False
    owner_lifo = False
    load_aware = True

    def score_matrix(self, sim: Simulator, ready: Sequence[Task]) -> np.ndarray:
        tids = [t.tid for t in ready]
        rows = sim.transfer_model.task_input_transfer_rows(
            sim.arrays, tids,
            [r.mem for r in sim.machine.resources], sim.residency,
        )
        return np.asarray(rows, dtype=np.float64) + class_duration_matrix(
            sim, tids
        )

    def tenant_scale(self, sim, ctx) -> float:
        return 1.0 / max(float(ctx.priority), 1e-9)


class WFQPolicy(PriorityPolicy):
    """Weighted-fair queueing over the same affinity scores.

    Classic WFQ virtual time: each tenant accumulates normalized service
    ``v[g] += duration / priority`` as its tasks are placed
    (``charge_tenant``); a new tenant starts at the pool minimum so it
    cannot claim infinite catch-up credit.  The backlog a tenant
    perceives is scaled by how far *ahead* of the least-served tenant it
    is — ahead tenants yield, behind tenants push — which bounds
    worst-case tenant slowdown (Jain fairness in
    ``repro.runtime.metrics.serving_report``) instead of letting heavy
    or high-priority tenants starve the tail.
    """

    name = "wfq"
    _EPS = 1e-6

    def __init__(self) -> None:
        self._vt: dict = {}

    def init(self, sim: Simulator) -> None:
        # reset per simulation: two runs with the same seed accumulate
        # identical virtual-time streams
        self._vt = {}

    def charge_tenant(self, ctx, dur: float) -> None:
        vt = self._vt
        gid = ctx.gid
        if gid not in vt:
            vt[gid] = min(vt.values()) if vt else 0.0
        vt[gid] += float(dur) / max(float(ctx.priority), 1e-9)

    def retire_tenant(self, ctx) -> None:
        # drop the finished tenant so the pool minimum tracks *live*
        # tenants only (a long-dead gid at v=0 would stall everyone)
        self._vt.pop(ctx.gid, None)

    def tenant_scale(self, sim, ctx) -> float:
        vt = self._vt
        v = vt.get(ctx.gid)
        if v is None:
            v = min(vt.values()) if vt else 0.0
            vt[ctx.gid] = v
        vmin = min(vt.values())
        eps = self._EPS
        scale = (eps + v) / (eps + vmin)
        return 1.0 if scale < 1.0 else (8.0 if scale > 8.0 else scale)


# ---------------------------------------------------------------------------
# score_matrix views for the ported strategies: HEFT and DADA expose the
# (ready × resources) matrices their placement logic is driven by, making
# the "one generic mechanism" claim inspectable (and giving the dist
# bridge a uniform surface); their `place` overrides stay authoritative.


def _heft_score_matrix(
    self: HEFT, sim: Simulator, ready: Sequence[Task]
) -> np.ndarray:
    """Earliest-finish-time scores: start + transfer (+ memory pressure
    under bounded capacity, as ``place`` folds it) + duration."""
    from repro.runtime.memory import pressure_rows_for

    tids = [t.tid for t in ready]
    resources = sim.machine.resources
    X = np.asarray(
        sim.transfer_model.task_input_transfer_rows(
            sim.arrays, tids, [r.mem for r in resources], sim.residency
        )
    )
    P = pressure_rows_for(sim, tids, resources)
    if P is not None:
        X = X + P
    dur = class_duration_matrix(sim, tids)
    start = np.array(
        [lt if lt > sim.now else sim.now for lt in sim.load_ts]
    )
    return start[None, :] + X + dur


def _dada_score_matrix(
    self: DADA, sim: Simulator, ready: Sequence[Task]
) -> np.ndarray:
    """DADA's λ-independent cost matrix C = class duration (+ predicted
    transfer under +CP) — the rows every ``try_build`` probe folds."""
    tids = [t.tid for t in ready]
    resources = sim.machine.resources
    cpus, gpus = sim.machine.cpus, sim.machine.gpus
    cpu_cls = cpus[0].cls if cpus else gpus[0].cls
    gpu_cls = gpus[0].cls if gpus else cpu_cls
    p_cpu = sim.predictor(cpu_cls).times_list(tids)
    p_gpu = sim.predictor(gpu_cls).times_list(tids)
    accel = np.array([r.is_accelerator for r in resources])
    C = np.where(
        accel[None, :],
        np.asarray(p_gpu)[:, None],
        np.asarray(p_cpu)[:, None],
    )
    if self.use_cp:
        from repro.runtime.memory import pressure_rows_for

        X = np.asarray(
            sim.transfer_model.task_input_transfer_rows(
                sim.arrays, tids, [r.mem for r in resources], sim.residency
            )
        )
        P = pressure_rows_for(sim, tids, resources)
        if P is not None:
            X = X + P
        C = C + X
    return C


def _no_score_matrix(self, sim: Simulator, ready: Sequence[Task]) -> None:
    """Work stealing is model-oblivious: there is no score matrix."""
    return None


HEFT.score_matrix = _heft_score_matrix
DADA.score_matrix = _dada_score_matrix  # DualApprox inherits
WorkSteal.score_matrix = _no_score_matrix


# ---------------------------------------------------------------------------
register("heft", HEFT)
register("dada", DADA)
register("dual", DualApprox)
register("ws", WorkSteal)
register("random", RandomPolicy)
register("locality", LocalityPolicy)
register("priority", PriorityPolicy)
register("wfq", WFQPolicy)
