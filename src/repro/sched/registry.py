"""Policy registry: ``register("name", factory)`` / ``resolve("name?k=v")``.

Replaces the if/elif ladder that ``repro.core.api.make_strategy`` used to
be. Policies are registered under short names; ``resolve`` accepts either a
bare name or a query-string spec (``"dada?alpha=0.25&use_cp=1"``) and
coerces every query value to the type the factory's signature declares —
``alpha=0.25`` arrives as a float, ``use_cp=1`` as a bool — so string specs
from CLIs/env/benchmark tables construct exactly the same objects as direct
Python calls.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, Optional, Tuple
from urllib.parse import parse_qsl, urlsplit

from .config import SchedConfig

_REGISTRY: Dict[str, Callable] = {}


def register(
    name: str, factory: Optional[Callable] = None, *, overwrite: bool = False
):
    """Register a policy factory under ``name`` (usable as a decorator).

    ``factory`` is any callable returning a policy (a class works).
    Re-registering an existing name raises unless ``overwrite=True`` —
    silent shadowing of a built-in policy is almost always a bug.
    """
    if factory is None:
        return lambda f: register(name, f, overwrite=overwrite)
    key = name.lower()
    if not overwrite and key in _REGISTRY:
        raise ValueError(
            f"policy {key!r} is already registered "
            "(pass overwrite=True to replace it)"
        )
    _REGISTRY[key] = factory
    return factory


def unregister(name: str) -> None:
    """Remove a registered policy (tests / plugin teardown)."""
    _REGISTRY.pop(name.lower(), None)


def registered() -> Tuple[str, ...]:
    """Registered policy names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_factory(name: str) -> Callable:
    try:
        return _REGISTRY[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r} (registered: {', '.join(registered())})"
        ) from None


# ---------------------------------------------------------------------------
# typed query-string coercion

_BOOL_TRUE = ("1", "true", "yes", "on")
_BOOL_FALSE = ("0", "false", "no", "off")


def _coerce_bool(spec: str, key: str, value: str) -> bool:
    v = value.lower()
    if v in _BOOL_TRUE:
        return True
    if v in _BOOL_FALSE:
        return False
    raise ValueError(f"policy spec {spec!r}: {key}={value!r} is not a boolean")


def _coerce(spec: str, key: str, value: str, param: inspect.Parameter):
    """Coerce ``value`` to the type the factory declares for ``key``.

    Annotations are strings (``from __future__ import annotations``), so
    the mapping is by name; when no annotation helps, fall back to the
    default's type, then to int/float/str literal inference.
    """
    ann = param.annotation
    ann_name = ann if isinstance(ann, str) else getattr(ann, "__name__", "")
    ann_name = (ann_name or "").replace("Optional[", "").rstrip("]")
    if ann_name == "bool" or isinstance(param.default, bool):
        return _coerce_bool(spec, key, value)
    if ann_name == "int" or (
        param.default is not inspect.Parameter.empty
        and isinstance(param.default, int)
        and not isinstance(param.default, bool)
    ):
        try:
            return int(value)
        except ValueError:
            raise ValueError(
                f"policy spec {spec!r}: {key}={value!r} is not an integer"
            ) from None
    if ann_name == "float" or isinstance(param.default, float):
        try:
            return float(value)
        except ValueError:
            raise ValueError(
                f"policy spec {spec!r}: {key}={value!r} is not a number"
            ) from None
    if ann_name == "str" or isinstance(param.default, str):
        return value
    # untyped: best-effort literal inference
    for conv in (int, float):
        try:
            return conv(value)
        except ValueError:
            pass
    return value


def parse_spec(spec: str) -> Tuple[str, Dict[str, str]]:
    """Split ``"name?k=v&k2=v2"`` into (name, raw query dict)."""
    parts = urlsplit(spec)
    name = (parts.path or "").strip().lower()
    if not name or parts.scheme or parts.netloc or parts.fragment:
        raise ValueError(f"malformed policy spec {spec!r} (expected 'name?k=v')")
    raw = {}
    for k, v in parse_qsl(parts.query, keep_blank_values=True):
        if k in raw:
            raise ValueError(f"policy spec {spec!r}: duplicate key {k!r}")
        raw[k] = v
    return name, raw


def resolve(
    spec,
    *,
    backend: Optional[str] = None,
    config: Optional[SchedConfig] = None,
    **kwargs,
):
    """Build a policy from a spec string (or pass a policy through).

    ``resolve("dada?alpha=0.25&use_cp=1")`` == ``DADA(alpha=0.25,
    use_cp=True)``. Extra ``kwargs`` merge with (and take precedence over)
    the query string. ``backend`` / ``config`` are forwarded to factories
    whose signature accepts them, so backend-free policies (``ws``,
    ``random``) need no boilerplate parameters.

    A non-string ``spec`` is assumed to already be a policy and returned
    unchanged — callers can accept "policy or spec" uniformly.
    """
    if not isinstance(spec, str):
        return spec
    name, raw = parse_spec(spec)
    factory = get_factory(name)
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):  # builtins without signatures
        sig = None
    call_kw = {}
    if sig is not None:
        params = sig.parameters
        has_var_kw = any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
        )
        for k, v in raw.items():
            p = params.get(k)
            if p is None and not has_var_kw:
                known = ", ".join(
                    n for n, q in params.items()
                    if q.kind
                    in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                        inspect.Parameter.KEYWORD_ONLY)
                )
                raise ValueError(
                    f"policy spec {spec!r}: unknown parameter {k!r} for "
                    f"{name!r} (accepts: {known})"
                )
            call_kw[k] = (
                _coerce(spec, k, v, p) if p is not None else v
            )
        if backend is not None and "backend" in params:
            call_kw.setdefault("backend", backend)
        if config is not None and "config" in params:
            call_kw.setdefault("config", config)
    else:
        call_kw.update(raw)
    call_kw.update(kwargs)
    return factory(**call_kw)
