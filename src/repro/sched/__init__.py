"""``repro.sched`` — the first-class scheduling policy API.

The paper's central mechanism — placement decided from per-(task ×
resource) completion-time and data-transfer scores — is the public
extension surface here:

  * :class:`Policy` / :class:`ScoreMatrixPolicy` — the protocol and the
    generic score-matrix placement driver (``docs/writing_a_policy.md``
    has a worked example);
  * :func:`register` / :func:`resolve` — the policy registry.
    ``resolve("dada?alpha=0.5&use_cp=1")`` replaces the old
    ``make_strategy`` if/elif ladder (which survives as a deprecated
    shim with bit-identical results);
  * :class:`SchedConfig` — every ``REPRO_SCHED_*``/``REPRO_BENCH_*`` knob
    parsed and validated in one place (:meth:`SchedConfig.from_env`),
    then threaded explicitly through the scheduling stack;
  * :func:`assign_from_scores` — the pure scores → assignment kernel,
    shared with ``repro.dist.sched_bridge``'s expert placement.

Built-in policies: ``heft``, ``dada``, ``dual``, ``ws`` (bit-for-bit equal
to ``repro.core._reference``), plus ``random``, ``locality``, and the
serving-tenant policies ``priority`` / ``wfq`` (weighted-fair queueing;
see ``repro.runtime.load``).
"""
from .config import KNOWN_ENV_VARS, SchedConfig, current_config
from .policy import Policy, ScoreMatrixPolicy, assign_from_scores
from .registry import (
    get_factory,
    parse_spec,
    register,
    registered,
    resolve,
    unregister,
)
from .policies import LocalityPolicy, PriorityPolicy, RandomPolicy, WFQPolicy

__all__ = [
    "KNOWN_ENV_VARS",
    "LocalityPolicy",
    "Policy",
    "PriorityPolicy",
    "RandomPolicy",
    "WFQPolicy",
    "SchedConfig",
    "ScoreMatrixPolicy",
    "assign_from_scores",
    "current_config",
    "get_factory",
    "parse_spec",
    "register",
    "registered",
    "resolve",
    "unregister",
]
