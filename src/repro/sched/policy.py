"""The ``Policy`` protocol: placement from (ready × resources) score matrices.

The paper's observation — developed further by Amaris et al.
(arXiv:1711.06433) for generic heterogeneous policies and by Wu et al.
(arXiv:1502.07451) for graph-partition/locality policies — is that HEFT and
DADA are two instances of *one* mechanism: every placement decision is a
function of per-(task × resource) completion-time and data-transfer scores.
This module makes that mechanism the public extension point:

  * :class:`Policy` — the structural protocol every scheduling policy
    satisfies (the simulator only ever calls ``init`` / ``place`` and reads
    the three class flags; ``score_matrix`` exposes the policy's scores for
    introspection, benchmarks and the distribution bridge);
  * :class:`ScoreMatrixPolicy` — a base class whose ``place`` is a generic
    driver: emit one score matrix over the array-native core, assign each
    task to its argmin resource (optionally load-aware, with ties broken by
    earliest finish). New policies implement ``score_matrix`` only — see
    ``docs/writing_a_policy.md`` for a worked 20-line example;
  * :func:`assign_from_scores` — the pure scores → assignment kernel,
    shared with ``repro.dist.sched_bridge`` (expert→group placement is the
    same mechanism with a per-column capacity).

HEFT / DADA keep their specialised ``place`` implementations (sequential
EFT scan, λ binary search) for bit-for-bit compatibility with the frozen
references, but expose their score matrices through the same method.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.simulator import Simulator, Strategy
from repro.core.dag import Task


@runtime_checkable
class Policy(Protocol):
    """Structural interface of a scheduling policy.

    Any object with these members schedules: the legacy ``Strategy``
    subclasses satisfy it unchanged, so ``isinstance(HEFT(), Policy)``
    holds without inheritance.
    """

    name: str
    allow_steal: bool
    owner_lifo: bool

    def init(self, sim: Simulator) -> None:
        """Called once before the simulation starts."""

    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        """Place newly-ready tasks (the paper's *activate* operation)."""

    def score_matrix(
        self, sim: Simulator, ready: Sequence[Task]
    ) -> Optional[np.ndarray]:
        """(ready × resources) placement scores, lower = better; ``None``
        for policies that do not score (e.g. work stealing)."""


def assign_from_scores(
    scores: np.ndarray,
    *,
    loads: Optional[np.ndarray] = None,
    costs: Optional[np.ndarray] = None,
    capacity: Optional[np.ndarray] = None,
    order: Optional[Sequence[int]] = None,
    return_loads: bool = False,
):
    """Greedy scores → assignment: the shared placement kernel.

    Each item ``i`` (in ``order``, default given order) goes to the column
    minimizing ``scores[i] + loads``; the chosen column's load then grows
    by ``costs[i, j]`` (default: the score itself), so the driver is
    load-aware whenever ``loads`` is supplied. ``capacity[j]`` bounds how
    many items a column may take (the expert-placement use in
    ``repro.dist.sched_bridge``). Ties go to the lowest column index
    (numpy argmin first-occurrence) — deterministic by construction.

    Returns the chosen column per item, in the items' original order
    (plus the final per-column loads when ``return_loads``).
    """
    S = np.asarray(scores, dtype=np.float64)
    n, m = S.shape
    if order is None:
        order = range(n)
    # load-aware only when the caller supplies loads: without them the
    # driver is a pure (capacity-masked) per-row argmin, no accumulation
    live_loads = (
        None if loads is None else np.asarray(loads, dtype=np.float64).copy()
    )
    remaining = None if capacity is None else np.asarray(capacity, dtype=np.int64).copy()
    choice = np.empty(n, dtype=np.int64)
    for i in order:
        row = S[i] if live_loads is None else S[i] + live_loads
        if remaining is not None:
            row = np.where(remaining > 0, row, np.inf)
        j = int(np.argmin(row))
        if not np.isfinite(row[j]):
            raise ValueError("assign_from_scores: no eligible column left")
        choice[i] = j
        if live_loads is not None:
            live_loads[j] += costs[i, j] if costs is not None else S[i, j]
        if remaining is not None:
            remaining[j] -= 1
    if return_loads:
        if live_loads is None:
            raise ValueError("return_loads requires loads")
        return choice, live_loads
    return choice


def class_duration_matrix(sim: Simulator, tids: Sequence[int]) -> np.ndarray:
    """(ready × resources) predicted durations from the cached per-class
    vector predictors (two lookups on the paper machine, one per class)."""
    cols = {}
    out = np.empty((len(tids), len(sim.machine.resources)), dtype=np.float64)
    for j, r in enumerate(sim.machine.resources):
        col = cols.get(r.cls.name)
        if col is None:
            col = cols[r.cls.name] = sim.predictor(r.cls).times_list(list(tids))
        out[:, j] = col
    return out


class ScoreMatrixPolicy(Strategy):
    """Base class: placement driven entirely by :meth:`score_matrix`.

    Subclasses emit one (ready × resources) score matrix per activation;
    the generic driver assigns each task to its minimum-score resource.
    With ``load_aware = True`` the driver adds the resources' predicted
    backlog (``sim.load_ts`` relative to now) to every score, charges the
    chosen resource the task's predicted duration, and keeps
    ``sim.load_ts`` up to date — the same shared time-stamps HEFT/DADA
    maintain (paper §2.3), so score policies compose with them.
    """

    allow_steal = False
    owner_lifo = False
    load_aware = False

    def score_matrix(self, sim: Simulator, ready: Sequence[Task]) -> np.ndarray:
        raise NotImplementedError

    def tenant_scale(self, sim, ctx) -> float:
        """Multiplier on the backlog term for ``ctx``'s tenant (> 0).

        ``1.0`` (the default) is plain load-aware placement.  Fairness
        policies override it: a scale < 1 lets a tenant see less of the
        shared backlog (it may queue behind others more aggressively), a
        scale > 1 makes a tenant yield.  Consumed by the load-aware
        driver below and by the serving pool's per-entry ranking
        (``repro.runtime.rescore``); optional companion hooks
        ``charge_tenant(ctx, dur)`` / ``retire_tenant(ctx)`` let a
        policy account per-tenant service (see :class:`WFQPolicy
        <repro.sched.policies.WFQPolicy>`).
        """
        return 1.0

    def pressure_matrix(
        self, sim: Simulator, ready: Sequence[Task]
    ) -> Optional[np.ndarray]:
        """(ready × resources) memory-pressure penalty, in seconds.

        ``None`` when device memories are unbounded (the default) and no
        resource is detached. Under a capacity
        (``REPRO_SCHED_MEM_CAPACITY``) each entry is the predicted
        eviction bytes placing the task there would force — its
        non-resident working set beyond the memory's free space — over
        the link bandwidth (see
        :meth:`repro.runtime.memory.MemoryManager.pressure_rows`).
        Detached resources (``repro.runtime.faults``) mask their columns
        to +inf, so every score policy avoids dead devices through this
        one channel. The generic driver adds it to every score matrix;
        override to weight or suppress the signal.
        """
        from repro.runtime.memory import pressure_rows_for

        return pressure_rows_for(
            sim, [t.tid for t in ready], sim.machine.resources
        )

    def place(self, sim: Simulator, ready: List[Task], src: Optional[int]) -> None:
        tids = [t.tid for t in ready]
        S = np.asarray(self.score_matrix(sim, ready), dtype=np.float64)
        if S.shape != (len(ready), len(sim.machine.resources)):
            raise ValueError(
                f"{self.name}: score matrix shape {S.shape} != "
                f"(ready={len(ready)}, resources={len(sim.machine.resources)})"
            )
        P = self.pressure_matrix(sim, ready)
        if P is not None:
            S = S + P
        if self.load_aware:
            now = sim.now
            offsets = np.array(
                [max(lt - now, 0.0) for lt in sim.load_ts], dtype=np.float64
            )
            dur = class_duration_matrix(sim, tids)
            ctx = getattr(sim, "_cur", None)
            scale = 1.0 if ctx is None else float(self.tenant_scale(sim, ctx))
            if scale == 1.0:
                choice, loads = assign_from_scores(
                    S, loads=offsets, costs=dur, return_loads=True
                )
                # charge the placements into the shared completion
                # time-stamps (paper §2.3) so interleaved strategies see
                # the backlog
                for j, load in enumerate(loads):
                    sim.load_ts[j] = now + float(load)
            else:
                # fairness scaling only biases the *choice*; the real
                # backlog charged into load_ts stays unscaled, or every
                # other tenant would see a distorted machine
                choice = assign_from_scores(
                    S, loads=offsets * scale, costs=dur * scale
                )
                for i in range(len(ready)):
                    j = int(choice[i])
                    sim.load_ts[j] = now + float(offsets[j]) + float(dur[i, j])
                    offsets[j] += dur[i, j]
            charge = getattr(self, "charge_tenant", None)
            if charge is not None and ctx is not None:
                for i in range(len(ready)):
                    charge(ctx, float(dur[i, int(choice[i])]))
            for i, t in enumerate(ready):
                sim.push(t, int(choice[i]))
        else:
            choice = assign_from_scores(S)
            for i, t in enumerate(ready):
                sim.push(t, int(choice[i]))
