"""Typed scheduling configuration — the single source of truth for every
``REPRO_SCHED_*`` / ``REPRO_BENCH_*`` knob.

Before this module the knobs were parsed ad hoc at ~10 call sites
(``backend.py`` read four env vars with silent fallbacks, the benchmark
harness another six): a typo like ``REPRO_SCHED_LAMBDA_DEPTH=banana``
silently became the platform default deep inside the jax backend.
``SchedConfig.from_env()`` parses the whole environment once, validates
every value, and rejects unknown ``REPRO_SCHED_*``/``REPRO_BENCH_*``
variables with one clear error, so misconfiguration fails at the edge
instead of deep in a hot path.

The frozen dataclass is then threaded explicitly through the scheduling
stack (``repro.core.backend`` / ``dada`` / ``heft`` / ``Simulator``) —
``os.environ`` is only ever read here.

``current_config()`` memoizes the parse against a snapshot of the relevant
environment entries, so hot paths pay a dict scan, not a re-parse, while
tests that monkeypatch the environment still see fresh values.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, fields
from typing import Mapping, Optional, Tuple

SCHED_PREFIX = "REPRO_SCHED_"
BENCH_PREFIX = "REPRO_BENCH_"

from repro.runtime.load import ADMISSION_MODES, ARRIVAL_PROCESSES
from repro.runtime.memory import EVICTION_POLICIES
from repro.runtime.rescore import RESCORE_MODES
from repro.runtime.traces import FAULT_MODES

BACKENDS = ("numpy", "jax")
PALLAS_MODES = ("auto", "1", "0", "off", "false")

# env var -> (field name, parser); parsers raise ValueError with the
# offending variable named, so the error reads as configuration feedback
_MISSING = object()


def _err(var: str, value: str, expected: str) -> ValueError:
    return ValueError(
        f"invalid scheduling configuration: {var}={value!r} ({expected})"
    )


def _parse_int(var: str, value: str, lo: Optional[int] = None) -> int:
    try:
        n = int(value)
    except ValueError:
        raise _err(var, value, "expected an integer") from None
    if lo is not None and n < lo:
        raise _err(var, value, f"expected an integer >= {lo}")
    return n


def _parse_float(var: str, value: str) -> float:
    try:
        return float(value)
    except ValueError:
        raise _err(var, value, "expected a number") from None


def _parse_flag(var: str, value: str) -> bool:
    if value in ("", "0"):
        return False
    if value == "1":
        return True
    raise _err(var, value, "expected 0 or 1")


def _parse_int_list(var: str, value: str, lo: int = 0) -> Tuple[int, ...]:
    out = []
    for part in value.split(","):
        part = part.strip()
        if not part:
            continue  # empty entries allowed: REPRO_BENCH_GPUS="" is an empty sweep
        out.append(_parse_int(var, part, lo))
    return tuple(out)


def _parse_str_list(var: str, value: str) -> Tuple[str, ...]:
    return tuple(p.strip() for p in value.split(",") if p.strip())


def _parse_rate(var: str, value: str) -> float:
    rate = _parse_float(var, value)
    if rate < 0:
        raise _err(var, value, "expected a rate >= 0")
    return rate


def _parse_trace_path(var: str, value: str) -> Optional[str]:
    if not value:
        return None  # empty = unset (no trace replay)
    if not os.path.isfile(value):
        raise _err(var, value, "expected a path to an existing JSONL trace file")
    return value


@dataclass(frozen=True)
class SchedConfig:
    """Every scheduling/benchmark knob, parsed and validated once.

    Scheduling (``REPRO_SCHED_*``):

    - ``backend``: placement-scoring backend, ``numpy`` (default) or
      ``jax``; see ``repro.core.backend``.
    - ``jax_min``: ready-set width from which the jax path engages.
    - ``lambda_depth``: speculative λ-bisection depth (``None`` = platform
      default: 1 on cpu, 5 on gpu/tpu), clamped to [1, 8].
    - ``pallas``: Pallas transfer-kernel mode (``auto``/``1``/``0``).
    - ``mem_capacity``: device-memory capacity in bytes (0 = unbounded,
      the default; see ``repro.runtime.memory``).
    - ``eviction``: victim-selection policy under capacity pressure,
      ``lru`` (default) or ``affinity`` (fewest pending readers first).
    - ``cancel_stale``: drop in-flight copies of data overwritten
      mid-flight instead of landing them as "valid" (off by default to
      preserve bit-for-bit equivalence with the reference simulator).
    - ``churn``: seeded random detach/attach rate in events per simulated
      second (0 = no churn, the default; see ``repro.runtime.faults``).
    - ``fault_mode``: recovery mode for detaches, ``drain`` (default) or
      ``kill`` (kill-and-requeue).
    - ``fault_trace``: path to a JSONL preemption trace replayed into
      every engine (``repro.runtime.traces``); must exist at parse time.
    - ``notice_s``: advance-warning window for detach events in simulated
      seconds (0 = no notice, the default). With a notice, the engine
      stops starting new work on the dying resource, proactively
      replicates sole-copy data to host, and policies see a finite
      decaying pressure penalty instead of a surprise death.
    - ``link_flake``: seeded per-hop transfer failure probability in
      [0, 1] (0 = reliable links, the default; see
      ``repro.runtime.transfers``).
    - ``retry_max``: failed-hop retry budget before the transfer times
      out and is re-sourced from another live copy or host.
    - ``backoff_s``: base delay for the capped exponential retry backoff
      (delay doubles per attempt, capped at 64×).
    - ``exact``: simulation engine selector. ``True`` (default) runs the
      exact Python event loop — the verification oracle. ``0`` opts into
      the batched surrogate episode engine (``repro.core.episode``),
      which requires the jax backend; ranking fidelity, not bit
      equality (see docs/runtime_architecture.md).
    - ``arrival``: open-loop arrival process for the serving load layer,
      ``poisson`` (default), ``bursty`` or ``diurnal``; consumed by
      ``repro.runtime.load.make_arrivals`` and the serving benchmark.
    - ``tenants``: tenant count for serving runs (0 = the consumer's
      default sweep; see ``benchmarks/serving_load.py``).
    - ``admission``: admission control at graph arrival, ``none``
      (default), ``reject`` (turn away tenants whose predicted working
      set exceeds free aggregate capacity) or ``defer`` (retry the
      arrival after ``admit_defer_s``); requires serving mode.
    - ``rescore``: serving-pool rescoring mode, ``off`` (default: the
      classic per-activation ``strategy.place`` loop, bit-for-bit
      identical to pre-serving engines), ``full`` (shared ready pool,
      every row rebuilt every round — the naive baseline) or
      ``incremental`` (dirty-row rescoring keyed on residency bitmasks
      and fault/pressure epochs; see ``repro.runtime.rescore``).
    - ``admit_defer_s``: simulated delay before a deferred arrival
      retries admission (> 0, or a deferred tenant would respin at the
      same instant forever).
    - ``audit``: record a structured schedule audit log on every engine
      (``repro.verify``): placements, transfer hops, landing decisions,
      evictions and fault windows, consumed by the independent schedule
      verifier. Off by default — audit-off runs are bit-for-bit
      identical to pre-audit behavior (see docs/verification.md).
    - ``jax_cache_dir``: mirror of ``JAX_COMPILATION_CACHE_DIR`` (the one
      non-``REPRO_*`` variable this config owns), so the surrogate
      engine's persistent-compilation-cache setup reads it from here
      instead of touching ``os.environ`` itself.
    - ``batch``: per-dispatch batch-size cap for the surrogate engine
      (``api.run_batch`` splits larger sweeps into chunks of this many
      configurations).
    - ``bench_backends``: backends the overhead benchmark measures.
    - ``regression_tol`` / ``row_tol``: throughput-gate tolerances.

    Benchmark harness (``REPRO_BENCH_*``): see ``benchmarks/common.py``;
    ``None`` means "unset" where the consumer's default depends on other
    knobs (e.g. runs defaults to 3 under ``bench_fast``, 30 otherwise).
    """

    # --- scheduling ----------------------------------------------------
    backend: str = "numpy"
    jax_min: int = 32
    lambda_depth: Optional[int] = None
    pallas: str = "auto"
    mem_capacity: int = 0
    eviction: str = "lru"
    cancel_stale: bool = False
    churn: float = 0.0
    fault_mode: str = "drain"
    fault_trace: Optional[str] = None
    notice_s: float = 0.0
    link_flake: float = 0.0
    retry_max: int = 3
    backoff_s: float = 1e-4
    exact: bool = True
    arrival: str = "poisson"
    tenants: int = 0
    admission: str = "none"
    rescore: str = "off"
    admit_defer_s: float = 0.005
    audit: bool = False
    jax_cache_dir: Optional[str] = None
    batch: int = 256
    bench_backends: Optional[Tuple[str, ...]] = None
    regression_tol: float = 0.25
    row_tol: float = 0.0
    # --- benchmark harness ---------------------------------------------
    bench_fast: bool = False
    bench_runs: Optional[int] = None
    bench_gpus: Optional[Tuple[int, ...]] = None
    bench_nt: Tuple[int, ...] = (16,)
    bench_jobs: Optional[int] = None
    bench_lambda: bool = True
    bench_lambda_nt: int = 64
    bench_lambda_reps: int = 3
    bench_allow_fail: bool = False

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise _err(
                "REPRO_SCHED_BACKEND", self.backend,
                f"choose from {BACKENDS}",
            )
        if self.pallas not in PALLAS_MODES:
            raise _err(
                "REPRO_SCHED_PALLAS", self.pallas,
                f"choose from {PALLAS_MODES}",
            )
        if self.eviction not in EVICTION_POLICIES:
            raise _err(
                "REPRO_SCHED_EVICTION", self.eviction,
                f"choose from {EVICTION_POLICIES}",
            )
        if self.churn < 0:
            raise _err(
                "REPRO_SCHED_CHURN", str(self.churn),
                "expected a rate >= 0",
            )
        if self.fault_mode not in FAULT_MODES:
            raise _err(
                "REPRO_SCHED_FAULT_MODE", self.fault_mode,
                f"choose from {FAULT_MODES}",
            )
        if self.notice_s < 0:
            raise _err(
                "REPRO_SCHED_NOTICE_S", str(self.notice_s),
                "expected a number >= 0",
            )
        if not (0.0 <= self.link_flake <= 1.0):
            raise _err(
                "REPRO_SCHED_LINK_FLAKE", str(self.link_flake),
                "expected a probability in [0, 1]",
            )
        if self.retry_max < 0:
            raise _err(
                "REPRO_SCHED_RETRY_MAX", str(self.retry_max),
                "expected an integer >= 0",
            )
        if self.backoff_s < 0:
            raise _err(
                "REPRO_SCHED_BACKOFF_S", str(self.backoff_s),
                "expected a number >= 0",
            )
        if self.arrival not in ARRIVAL_PROCESSES:
            raise _err(
                "REPRO_SCHED_ARRIVAL", self.arrival,
                f"choose from {ARRIVAL_PROCESSES}",
            )
        if self.tenants < 0:
            raise _err(
                "REPRO_SCHED_TENANTS", str(self.tenants),
                "expected an integer >= 0",
            )
        if self.admission not in ADMISSION_MODES:
            raise _err(
                "REPRO_SCHED_ADMISSION", self.admission,
                f"choose from {ADMISSION_MODES}",
            )
        if self.rescore not in RESCORE_MODES:
            raise _err(
                "REPRO_SCHED_RESCORE", self.rescore,
                f"choose from {RESCORE_MODES}",
            )
        if not (self.admit_defer_s > 0):
            raise _err(
                "REPRO_SCHED_ADMIT_DEFER_S", str(self.admit_defer_s),
                "expected a number > 0",
            )
        if not self.exact and self.backend != "jax":
            # the surrogate episode engine is a jax program; a silent
            # fall-back to the exact path would invert the knob's meaning
            raise ValueError(
                "invalid scheduling configuration: REPRO_SCHED_EXACT=0 "
                "(the batched surrogate engine) requires "
                "REPRO_SCHED_BACKEND=jax, got "
                f"REPRO_SCHED_BACKEND={self.backend!r}"
            )
        if self.lambda_depth is not None:
            object.__setattr__(
                self, "lambda_depth", max(1, min(int(self.lambda_depth), 8))
            )

    # ------------------------------------------------------------------
    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None) -> "SchedConfig":
        """Parse (and validate) the environment into a ``SchedConfig``.

        Raises ``ValueError`` naming the offending variable for malformed
        values *and* for unknown ``REPRO_SCHED_*``/``REPRO_BENCH_*``
        variables — a typoed knob must not silently do nothing.
        """
        if env is None:
            env = os.environ
        kw = {}
        unknown = []
        for var, raw in env.items():
            if not (var.startswith(SCHED_PREFIX) or var.startswith(BENCH_PREFIX)):
                continue
            spec = _ENV_SCHEMA.get(var)
            if spec is None:
                unknown.append(var)
                continue
            field_name, parse = spec
            kw[field_name] = parse(var, raw)
        if unknown:
            known = ", ".join(sorted(_ENV_SCHEMA))
            raise ValueError(
                "unknown scheduling configuration variable(s): "
                f"{', '.join(sorted(unknown))} (known: {known})"
            )
        # non-REPRO-prefixed variables this config mirrors (jax owns the
        # name; we only read it so sched/config.py stays the single env
        # source and the repo lint needs no exception for episode.py)
        raw = env.get("JAX_COMPILATION_CACHE_DIR")
        if raw:
            kw["jax_cache_dir"] = raw
        return cls(**kw)

    def env_items(self) -> Tuple[Tuple[str, str], ...]:
        """The env-var form of every non-default field (for subprocesses)."""
        defaults = SchedConfig()
        out = []
        for f in fields(self):
            v = getattr(self, f.name)
            if v == getattr(defaults, f.name):
                continue
            var = _FIELD_TO_ENV[f.name]
            if isinstance(v, tuple):
                s = ",".join(str(x) for x in v)
            elif isinstance(v, bool):
                s = "1" if v else "0"
            else:
                s = str(v)
            out.append((var, s))
        return tuple(out)


_ENV_SCHEMA = {
    "REPRO_SCHED_BACKEND": ("backend", lambda var, v: v.lower()),
    "REPRO_SCHED_JAX_MIN": ("jax_min", lambda var, v: _parse_int(var, v, lo=1)),
    "REPRO_SCHED_LAMBDA_DEPTH": (
        "lambda_depth", lambda var, v: _parse_int(var, v)),
    "REPRO_SCHED_PALLAS": ("pallas", lambda var, v: v.lower()),
    "REPRO_SCHED_MEM_CAPACITY": (
        "mem_capacity", lambda var, v: _parse_int(var, v, lo=0)),
    "REPRO_SCHED_EVICTION": ("eviction", lambda var, v: v.lower()),
    "REPRO_SCHED_CANCEL_STALE": ("cancel_stale", _parse_flag),
    "REPRO_SCHED_CHURN": ("churn", _parse_rate),
    "REPRO_SCHED_FAULT_MODE": ("fault_mode", lambda var, v: v.lower()),
    "REPRO_SCHED_FAULT_TRACE": ("fault_trace", _parse_trace_path),
    "REPRO_SCHED_NOTICE_S": ("notice_s", _parse_rate),
    "REPRO_SCHED_LINK_FLAKE": ("link_flake", _parse_rate),
    "REPRO_SCHED_RETRY_MAX": (
        "retry_max", lambda var, v: _parse_int(var, v, lo=0)),
    "REPRO_SCHED_BACKOFF_S": ("backoff_s", _parse_rate),
    "REPRO_SCHED_EXACT": ("exact", _parse_flag),
    "REPRO_SCHED_ARRIVAL": ("arrival", lambda var, v: v.lower()),
    "REPRO_SCHED_TENANTS": (
        "tenants", lambda var, v: _parse_int(var, v, lo=0)),
    "REPRO_SCHED_ADMISSION": ("admission", lambda var, v: v.lower()),
    "REPRO_SCHED_RESCORE": ("rescore", lambda var, v: v.lower()),
    "REPRO_SCHED_ADMIT_DEFER_S": ("admit_defer_s", _parse_rate),
    "REPRO_SCHED_AUDIT": ("audit", _parse_flag),
    "REPRO_SCHED_BATCH": ("batch", lambda var, v: _parse_int(var, v, lo=1)),
    "REPRO_SCHED_BACKENDS": ("bench_backends", _parse_str_list),
    "REPRO_SCHED_REGRESSION_TOL": ("regression_tol", _parse_float),
    "REPRO_SCHED_ROW_TOL": (
        "row_tol", lambda var, v: _parse_float(var, v) if v else 0.0),
    "REPRO_BENCH_FAST": ("bench_fast", _parse_flag),
    "REPRO_BENCH_RUNS": ("bench_runs", lambda var, v: _parse_int(var, v, lo=1)),
    "REPRO_BENCH_GPUS": ("bench_gpus", _parse_int_list),
    "REPRO_BENCH_NT": ("bench_nt", lambda var, v: _parse_int_list(var, v, lo=1)),
    "REPRO_BENCH_JOBS": ("bench_jobs", lambda var, v: _parse_int(var, v, lo=1)),
    "REPRO_BENCH_LAMBDA": ("bench_lambda", _parse_flag),
    "REPRO_BENCH_LAMBDA_NT": (
        "bench_lambda_nt", lambda var, v: _parse_int(var, v, lo=1)),
    "REPRO_BENCH_LAMBDA_REPS": (
        "bench_lambda_reps", lambda var, v: _parse_int(var, v, lo=1)),
    "REPRO_BENCH_ALLOW_FAIL": ("bench_allow_fail", _parse_flag),
}

_FIELD_TO_ENV = {field: var for var, (field, _) in _ENV_SCHEMA.items()}
# mirrored non-REPRO variables (special-cased in from_env)
_FIELD_TO_ENV["jax_cache_dir"] = "JAX_COMPILATION_CACHE_DIR"

KNOWN_ENV_VARS: Tuple[str, ...] = tuple(sorted(_ENV_SCHEMA))


# ---------------------------------------------------------------------------
# memoized accessor: one parse per environment state

_CACHE: Optional[Tuple[Tuple[Tuple[str, str], ...], SchedConfig]] = None


def _env_snapshot() -> Tuple[Tuple[str, str], ...]:
    return tuple(
        sorted(
            (k, v)
            for k, v in os.environ.items()
            if k.startswith(SCHED_PREFIX)
            or k.startswith(BENCH_PREFIX)
            or k == "JAX_COMPILATION_CACHE_DIR"
        )
    )


def current_config() -> SchedConfig:
    """The process-wide ``SchedConfig`` derived from the environment.

    Re-parses only when a relevant environment entry changed (tests
    monkeypatching ``REPRO_*`` see fresh values immediately); otherwise
    returns the memoized instance, so call sites can treat this as cheap.
    """
    global _CACHE
    snap = _env_snapshot()
    if _CACHE is not None and _CACHE[0] == snap:
        return _CACHE[1]
    cfg = SchedConfig.from_env()
    _CACHE = (snap, cfg)
    return cfg


def _reset_config_cache() -> None:
    """Test hook: forget the memoized environment parse."""
    global _CACHE
    _CACHE = None
