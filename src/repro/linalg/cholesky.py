"""Tile Cholesky (PLASMA DPOTRF, right-looking) as a data-flow task graph.

Task kinds / flop counts (tile size b):
  potrf  b^3/3      trsm  b^3      syrk  b^3      gemm  2 b^3
Total ~ n^3/3 for an n x n matrix — the standard Cholesky count the paper's
GFLOPS plots use.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

from repro.core.dag import Mode, TaskGraph

from .tiles import make_tile_objects

# jax is imported inside the tile kernels: the scheduler-only path
# (with_fns=False, used by every benchmark sweep) never pays the ~0.8s
# jax import.


def _potrf(a_kk):
    import jax.numpy as jnp

    return (jnp.linalg.cholesky(a_kk),)


def _trsm(l_kk, a_ik):
    import jax

    # A[i,k] <- A[i,k] * L[k,k]^{-T}
    x = jax.scipy.linalg.solve_triangular(l_kk, a_ik.T, lower=True)
    return (x.T,)


def _syrk(a_ik, a_ii):
    return (a_ii - a_ik @ a_ik.T,)


def _gemm(a_ik, a_jk, a_ij):
    return (a_ij - a_ik @ a_jk.T,)


def cholesky_graph(
    n_tiles: int, tile: int = 512, itemsize: int = 8, with_fns: bool = True
) -> TaskGraph:
    """Build the tile-Cholesky DAG for an (n_tiles*tile)^2 matrix."""
    g = TaskGraph()
    A = make_tile_objects("A", n_tiles, tile, itemsize)
    b3 = float(tile) ** 3
    fns = with_fns
    for k in range(n_tiles):
        g.add_task(
            "potrf",
            [(A[(k, k)], Mode.RW)],
            flops=b3 / 3.0,
            fn=_potrf if fns else None,
            tag=("potrf", k),
        )
        for i in range(k + 1, n_tiles):
            g.add_task(
                "trsm",
                [(A[(k, k)], Mode.R), (A[(i, k)], Mode.RW)],
                flops=b3,
                fn=_trsm if fns else None,
                tag=("trsm", i, k),
            )
        for i in range(k + 1, n_tiles):
            g.add_task(
                "syrk",
                [(A[(i, k)], Mode.R), (A[(i, i)], Mode.RW)],
                flops=b3,
                fn=_syrk if fns else None,
                tag=("syrk", i, k),
            )
            for j in range(k + 1, i):
                g.add_task(
                    "gemm",
                    [
                        (A[(i, k)], Mode.R),
                        (A[(j, k)], Mode.R),
                        (A[(i, j)], Mode.RW),
                    ],
                    flops=2.0 * b3,
                    fn=_gemm if fns else None,
                    tag=("gemm", i, j, k),
                )
    return g


def reference_flops(n: int) -> float:
    return n**3 / 3.0
