"""Tile LU (PLASMA DGETRF task shape) as a data-flow task graph.

Task kinds / flop counts (tile size b):
  getrf  2/3 b^3    gessm  b^3     tstrf  b^3     ssssm  2 b^3
Total ~ 2 n^3 / 3.

Execution note (DESIGN.md §2): PLASMA's DGETRF uses *incremental pivoting*
inside TSTRF/SSSSM; TPU-friendly execution here uses the no-pivot
right-looking block LU, which has the *same task/dependency shape* (what the
scheduler sees) and is numerically safe on the diagonally-dominant test
matrices used by the tests. The simulator costs remain the PLASMA ones.
"""
from __future__ import annotations

from repro.core.dag import Mode, TaskGraph

from .tiles import make_tile_objects


def _getrf(a_kk):
    """No-pivot in-tile LU: returns packed L\\U (unit lower not stored)."""
    import jax
    import jax.numpy as jnp

    def body(k, a):
        col = a[:, k] / a[k, k]
        col = jnp.where(jnp.arange(a.shape[0]) > k, col, a[:, k])
        a = a.at[:, k].set(col)
        update = jnp.outer(
            jnp.where(jnp.arange(a.shape[0]) > k, a[:, k], 0.0),
            jnp.where(jnp.arange(a.shape[1]) > k, a[k, :], 0.0),
        )
        return a - update

    n = a_kk.shape[0]
    return (jax.lax.fori_loop(0, n, body, a_kk),)


def _split_lu(packed):
    import jax.numpy as jnp

    l = jnp.tril(packed, -1) + jnp.eye(packed.shape[0], dtype=packed.dtype)
    u = jnp.triu(packed)
    return l, u


def _gessm(a_kk, a_kj):
    import jax

    l, _ = _split_lu(a_kk)
    return (jax.scipy.linalg.solve_triangular(l, a_kj, lower=True, unit_diagonal=True),)


def _tstrf(a_kk, a_ik):
    import jax

    _, u = _split_lu(a_kk)
    # A[i,k] <- A[i,k] U^{-1}
    x = jax.scipy.linalg.solve_triangular(u.T, a_ik.T, lower=True)
    return (x.T,)


def _ssssm(a_ik, a_kj, a_ij):
    return (a_ij - a_ik @ a_kj,)


def lu_graph(
    n_tiles: int, tile: int = 512, itemsize: int = 8, with_fns: bool = True
) -> TaskGraph:
    g = TaskGraph()
    A = make_tile_objects("A", n_tiles, tile, itemsize)
    b3 = float(tile) ** 3
    fns = with_fns
    for k in range(n_tiles):
        g.add_task(
            "getrf",
            [(A[(k, k)], Mode.RW)],
            flops=2.0 * b3 / 3.0,
            fn=_getrf if fns else None,
            tag=("getrf", k),
        )
        for j in range(k + 1, n_tiles):
            g.add_task(
                "gessm",
                [(A[(k, k)], Mode.R), (A[(k, j)], Mode.RW)],
                flops=b3,
                fn=_gessm if fns else None,
                tag=("gessm", k, j),
            )
        for i in range(k + 1, n_tiles):
            g.add_task(
                "tstrf",
                [(A[(k, k)], Mode.R), (A[(i, k)], Mode.RW)],
                flops=b3,
                fn=_tstrf if fns else None,
                tag=("tstrf", i, k),
            )
            for j in range(k + 1, n_tiles):
                g.add_task(
                    "ssssm",
                    [
                        (A[(i, k)], Mode.R),
                        (A[(k, j)], Mode.R),
                        (A[(i, j)], Mode.RW),
                    ],
                    flops=2.0 * b3,
                    fn=_ssssm if fns else None,
                    tag=("ssssm", i, j, k),
                )
    return g


def reference_flops(n: int) -> float:
    return 2.0 * n**3 / 3.0
