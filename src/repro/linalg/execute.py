"""Execute a data-flow task graph on real JAX arrays.

Two modes:
  * ``execute_graph``: program (topological) order — the semantic reference;
  * ``execute_schedule``: replay the exact per-worker interval order produced
    by a simulation, asserting it is precedence-safe. Identical results prove
    the scheduler's orders are *valid linearizations* of the DAG.

Task bodies receive the current arrays of their accesses (in access order)
and return new arrays for their write accesses (in order).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core.dag import TaskGraph
from repro.core.simulator import SimResult


def _run_task(task, store: Dict[str, jnp.ndarray]) -> None:
    if task.fn is None:
        raise ValueError(f"{task} has no executable body")
    # convention: bodies receive arrays for *reading* accesses (R/RW) in
    # access order and return arrays for *writing* accesses (W/RW) in order
    args = [store[a.data.name] for a in task.accesses if a.mode.reads]
    outs = task.fn(*args)
    writes = [a.data.name for a in task.accesses if a.mode.writes]
    if len(outs) != len(writes):
        raise ValueError(
            f"{task}: body returned {len(outs)} outputs for {len(writes)} writes"
        )
    for name, val in zip(writes, outs):
        store[name] = val


def execute_graph(
    graph: TaskGraph, arrays: Dict[str, jnp.ndarray]
) -> Dict[str, jnp.ndarray]:
    store = dict(arrays)
    for tid in graph.topo_order():
        _run_task(graph.tasks[tid], store)
    return store


def execute_schedule(
    graph: TaskGraph,
    arrays: Dict[str, jnp.ndarray],
    result: SimResult,
) -> Dict[str, jnp.ndarray]:
    """Replay a simulated schedule (global start-time order) and check that
    every task starts only after all its predecessors finished."""
    order = sorted(result.intervals, key=lambda iv: (iv.start, iv.tid))
    end_time = {iv.tid: iv.end for iv in result.intervals}
    store = dict(arrays)
    done = set()
    for iv in order:
        for p in graph.pred[iv.tid]:
            if p not in done:
                raise AssertionError(
                    f"schedule violates precedence: task {iv.tid} started at "
                    f"{iv.start} before predecessor {p} finished"
                )
            if end_time[p] > iv.start + 1e-9:
                raise AssertionError(
                    f"overlap: task {iv.tid} starts {iv.start} < pred {p} "
                    f"ends {end_time[p]}"
                )
        _run_task(graph.tasks[iv.tid], store)
        done.add(iv.tid)
    if len(done) != len(graph):
        raise AssertionError("schedule did not execute every task")
    return store
