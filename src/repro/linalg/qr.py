"""Tile QR (PLASMA DGEQRF, flat reduction tree) as a data-flow task graph.

Task kinds / flop counts (tile size b):
  geqrt  4/3 b^3   ormqr  2 b^3   tsqrt  10/3 b^3   tsmqr  4 b^3
Leading-order total ~ 4 n^3 / 3 (tsmqr dominates), matching the tile-QR
flop count used in the paper's GFLOPS plots.

Execution note: the executable bodies store explicit Q factors in the T-tile
slots (T[k,k]: b x b, T[i,k]: 2b x 2b) instead of LAPACK's compact-WY (V,T)
pair — numerically identical, simpler in JAX. The *scheduler* still sees
PLASMA's T-tile sizes (ib x b) so simulated transfer volumes stay faithful.
"""
from __future__ import annotations

from repro.core.dag import DataObject, Mode, TaskGraph

from .tiles import make_tile_objects, tile_name


def _geqrt(a_kk):
    import jax.numpy as jnp

    q, r = jnp.linalg.qr(a_kk, mode="complete")
    return (r, q)  # writes: A[k,k] <- R, T[k,k] <- Q


def _ormqr(q_kk, a_kj):
    return (q_kk.T @ a_kj,)


def _tsqrt(a_kk, a_ik):
    import jax.numpy as jnp

    b = a_kk.shape[0]
    s = jnp.concatenate([a_kk, a_ik], axis=0)  # (2b, b)
    q, r = jnp.linalg.qr(s, mode="complete")  # q: (2b,2b) r: (2b,b)
    return (r[:b], jnp.zeros_like(a_ik), q)  # A[k,k]<-R, A[i,k]<-0, T[i,k]<-Q


def _tsmqr(q_ik, a_kj, a_ij):
    import jax.numpy as jnp

    b = a_kj.shape[0]
    s = jnp.concatenate([a_kj, a_ij], axis=0)
    s = q_ik.T @ s
    return (s[:b], s[b:])


def qr_graph(
    n_tiles: int,
    tile: int = 512,
    inner_block: int = 128,
    itemsize: int = 8,
    with_fns: bool = True,
) -> TaskGraph:
    g = TaskGraph()
    A = make_tile_objects("A", n_tiles, tile, itemsize)
    # T tiles: PLASMA stores ib x b blocks of the block reflectors
    T = {
        (i, k): DataObject(
            name=tile_name("T", i, k),
            size_bytes=inner_block * tile * itemsize,
            meta=("T", i, k),
        )
        for i in range(n_tiles)
        for k in range(n_tiles)
    }
    b3 = float(tile) ** 3
    fns = with_fns
    for k in range(n_tiles):
        g.add_task(
            "geqrt",
            [(A[(k, k)], Mode.RW), (T[(k, k)], Mode.W)],
            flops=4.0 * b3 / 3.0,
            fn=_geqrt if fns else None,
            tag=("geqrt", k),
        )
        for j in range(k + 1, n_tiles):
            g.add_task(
                "ormqr",
                [(T[(k, k)], Mode.R), (A[(k, j)], Mode.RW)],
                flops=2.0 * b3,
                fn=_ormqr if fns else None,
                tag=("ormqr", k, j),
            )
        for i in range(k + 1, n_tiles):
            g.add_task(
                "tsqrt",
                [(A[(k, k)], Mode.RW), (A[(i, k)], Mode.RW), (T[(i, k)], Mode.W)],
                flops=10.0 * b3 / 3.0,
                fn=_tsqrt if fns else None,
                tag=("tsqrt", i, k),
            )
            for j in range(k + 1, n_tiles):
                g.add_task(
                    "tsmqr",
                    [
                        (T[(i, k)], Mode.R),
                        (A[(k, j)], Mode.RW),
                        (A[(i, j)], Mode.RW),
                    ],
                    flops=4.0 * b3,
                    fn=_tsmqr if fns else None,
                    tag=("tsmqr", i, j, k),
                )
    return g


def reference_flops(n: int) -> float:
    return 4.0 * n**3 / 3.0
