"""Tiled-matrix helpers (PLASMA-style square tiles)."""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.dag import DataObject


def tile_name(label: str, i: int, j: int) -> str:
    return f"{label}[{i},{j}]"


def make_tile_objects(
    label: str, n_tiles: int, tile: int, itemsize: int = 8
) -> Dict[Tuple[int, int], DataObject]:
    """DataObjects for an n_tiles x n_tiles tiled matrix."""
    objs = {}
    for i in range(n_tiles):
        for j in range(n_tiles):
            objs[(i, j)] = DataObject(
                name=tile_name(label, i, j),
                size_bytes=tile * tile * itemsize,
                meta=(label, i, j),
            )
    return objs


def split_tiles(a, tile: int) -> Dict[str, "jnp.ndarray"]:
    """Split a square matrix into named tiles A[i,j]."""
    n = a.shape[0]
    assert a.shape == (n, n) and n % tile == 0
    nt = n // tile
    out = {}
    for i in range(nt):
        for j in range(nt):
            out[tile_name("A", i, j)] = a[
                i * tile : (i + 1) * tile, j * tile : (j + 1) * tile
            ]
    return out


def join_tiles(tiles: Dict[str, "jnp.ndarray"], nt: int, tile: int) -> "jnp.ndarray":
    import jax.numpy as jnp

    rows = []
    for i in range(nt):
        rows.append(
            jnp.concatenate([tiles[tile_name("A", i, j)] for j in range(nt)], axis=1)
        )
    return jnp.concatenate(rows, axis=0)


def random_spd(n: int, seed: int = 0, dtype=None) -> "jnp.ndarray":
    """Symmetric positive-definite test matrix."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    spd = a @ a.T / n + np.eye(n) * n
    return jnp.asarray(spd, dtype=dtype or jnp.float64)


def random_dd(n: int, seed: int = 0, dtype=None) -> "jnp.ndarray":
    """Diagonally-dominant matrix (safe for no-pivot LU)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    a = a + np.eye(n) * (np.abs(a).sum(axis=1).max() + n)
    return jnp.asarray(a, dtype=dtype or jnp.float64)


def random_dense(n: int, seed: int = 0, dtype=None) -> "jnp.ndarray":
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, n)), dtype=dtype or jnp.float64)
